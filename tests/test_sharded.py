"""Multi-shard scale-out (``repro.distributed``): sharded plan
execution must be bit-identical to the unsharded index on every kind,
cross-stream admission must serialize conflicting plans and co-admit
disjoint ones, a crash inside one shard's group commit must stay in
that shard (siblings keep serving stale-free with no replay; recovery
replays exactly the crashed shard's sub-plan), the mesh read fan-out
must match the per-shard path, and the per-shard span attribution must
sum exactly to the aggregate ``ShardedPMem`` counters."""

import numpy as np
import pytest

from repro import obs
from repro.core import (CrashPoint, PART, PBwTree, PCLHT, PHOT, PMasstree,
                        PMem, Plan)
from repro.core.baselines import CCEH
from repro.distributed import ShardedIndex, StreamDriver

# all five RECIPE conversions plus the hand-crafted CCEH baseline —
# the sharded layer treats them uniformly through the plan surface
FACTORIES = [
    ("P-CLHT", lambda p: PCLHT(p, n_buckets=64)),
    ("P-ART", PART),
    ("P-HOT", PHOT),
    ("P-Masstree", PMasstree),
    ("P-BwTree", PBwTree),
    ("CCEH", lambda p: CCEH(p, depth=2, fixed=True)),
]


def _random_plan(rng, n, n_keys, *, scans):
    kinds = rng.integers(0, 5 if scans else 4, size=n).astype(np.int32)
    keys = rng.integers(1, n_keys, size=n).astype(np.int64)
    aux = rng.integers(1, 50, size=n).astype(np.int64)
    return Plan.from_arrays(kinds, keys, aux)


def _load(idx, keys, base=1000):
    plan = Plan()
    for k in keys:
        plan.put(int(k), int(k) + base)
    idx.execute(plan, collect_results=False)


# -- equivalence ----------------------------------------------------------

@pytest.mark.parametrize("name,factory", FACTORIES)
def test_sharded_plan_equivalence(name, factory):
    """Mixed plans on a 4-shard index return exactly what the
    unsharded index returns — results, tallies, and final contents."""
    rng = np.random.default_rng(11)
    solo = factory(PMem())
    sharded = ShardedIndex(factory, 4)
    scans = solo.ORDERED
    for _ in range(3):
        plan = _random_plan(rng, 200, 500, scans=scans)
        r1 = solo.execute(plan)
        r2 = sharded.execute(plan)
        assert r1.results == r2.results
        assert (r1.found, r1.acked, r1.scanned) == \
            (r2.found, r2.acked, r2.scanned)
    assert sorted(solo.items()) == sorted(sharded.items())
    sharded.check_invariants()
    assert sharded.stats["plans"] == 3
    assert sharded.n_shards == 4


def test_sharded_scan_merge_hash_scheme():
    """Hash routing interleaves an ordered index's key ranges across
    shards: the merge-sort scan merge must still be exact."""
    rng = np.random.default_rng(12)
    solo = PART(PMem())
    sharded = ShardedIndex(PART, 4, scheme="hash")
    assert sharded.scheme == "hash"
    for _ in range(2):
        plan = _random_plan(rng, 150, 300, scans=True)
        r1 = solo.execute(plan)
        r2 = sharded.execute(plan)
        assert r1.results == r2.results
        assert r1.scanned == r2.scanned
    assert sharded.stats["scan_merges"] > 0


def test_prefix_routing_keeps_items_globally_sorted():
    sharded = ShardedIndex(PBwTree, 4)  # ordered -> prefix scheme
    assert sharded.scheme == "prefix"
    keys = np.random.default_rng(0).integers(1, 1 << 60, 500)
    _load(sharded, np.unique(keys))
    merged = list(sharded.items())
    assert merged == sorted(merged)


# -- multi-stream admission -----------------------------------------------

def test_streams_conflicting_plans_serialize():
    """Write/write and read/write on one key must never co-admit: the
    driver defers the conflicting head and retries next tick, so each
    stream sees a serial order."""
    idx = ShardedIndex(lambda p: PCLHT(p, n_buckets=64), 2)
    drv = StreamDriver(idx, 2)
    s0, s1 = drv.streams
    k = 42
    t_put0 = s0.submit(Plan.from_ops([("insert", k, 1)]))
    t_get0 = s0.submit(Plan.from_ops([("lookup", k, 0)]))
    t_put1 = s1.submit(Plan.from_ops([("insert", k, 2)]))
    t_get1 = s1.submit(Plan.from_ops([("lookup", k, 0)]))
    drv.run()
    assert drv.stats["deferred_plans"] > 0
    # per-stream program order: each get ran after its stream's put
    assert t_get0.tick > t_put0.tick and t_get1.tick > t_put1.tick
    # the puts serialized (conflicting writes never share a tick)
    assert t_put0.tick != t_put1.tick
    # insert is insert-if-absent: the FIRST admitted put wins, the
    # second is a no-op ack=False — both gets observe the winner
    first, want = ((t_put0, 1) if t_put0.tick < t_put1.tick
                   else (t_put1, 2))
    assert first.result == [True]
    assert t_get0.result == [want] and t_get1.result == [want]


def test_streams_disjoint_plans_coadmit():
    idx = ShardedIndex(lambda p: PCLHT(p, n_buckets=64), 2)
    drv = StreamDriver(idx, 3)
    tickets = [drv.streams[i].submit(
        Plan.from_ops([("insert", 100 + i, i)])) for i in range(3)]
    drv.run()
    assert drv.stats["ticks"] == 1
    assert drv.stats["multi_stream_ticks"] == 1
    assert drv.stats["deferred_plans"] == 0
    assert all(t.result == [True] for t in tickets)


def test_streams_match_sequential_oracle():
    """Disjoint-keyed random plans across 4 streams produce exactly
    the results of running each stream's plans alone, in order — the
    conflict-freedom guarantee of per-tick admission."""
    rng = np.random.default_rng(5)
    idx = ShardedIndex(lambda p: PCLHT(p, n_buckets=64), 4)
    solo = PCLHT(PMem(), n_buckets=64)
    drv = StreamDriver(idx, 4)
    plans, tickets = [], []
    for i in range(4):
        # each stream owns a disjoint key range; ops within it are
        # random, so streams are order-independent by construction
        for _ in range(3):
            plan = _random_plan(rng, 40, 100, scans=False)
            kinds, keys, aux = plan.arrays()
            plan = Plan.from_arrays(kinds, keys + 1000 * i, aux)
            plans.append(plan)
            tickets.append(drv.streams[i].submit(plan))
    drv.run()
    for plan, ticket in zip(plans, tickets):
        assert ticket.result == solo.execute(plan).results
    assert sorted(idx.items()) == sorted(solo.items())


# -- per-shard crash isolation --------------------------------------------

@pytest.mark.parametrize("name,factory", FACTORIES)
def test_per_shard_crash_is_isolated(name, factory):
    """Crash one shard mid-group-commit during a cross-shard update
    plan: siblings finish their sub-plans and serve the new values
    stale-free with NO replay; recovery replays exactly the crashed
    shard's sub-plan and nothing of the siblings'."""
    rng = np.random.default_rng(7)
    idx = ShardedIndex(factory, 4)
    keys = np.unique(rng.integers(1, 1 << 60, 300))
    _load(idx, keys)
    routes = idx.route(keys)
    upd = Plan()
    for k in keys:
        upd.update(int(k), int(k) + 5555)
    victim = int(routes[0])
    idx.pmems[victim].arm_crash(after_stores=3)
    with pytest.raises(CrashPoint):
        idx.execute(upd, collect_results=False)
    assert idx.last_crashed_shard == victim
    assert all(pm.crashes == 0 for s, pm in enumerate(idx.pmems)
               if s != victim)
    # sibling shards completed their sub-plans: stale-free reads of the
    # NEW values, without any recovery or replay anywhere
    sib = [int(k) for k, r in zip(keys, routes) if r != victim]
    gets = Plan.from_ops([("lookup", k, 0) for k in sib])
    res = idx.execute(gets)
    assert res.results == [k + 5555 for k in sib]
    # power-fail ONLY the crashed shard, then replay exactly its
    # pending sub-plan on top of its plan-prefix-consistent image
    idx.crash_shard(victim)
    replayed = idx.recover_shard(victim)
    assert replayed == int((routes == victim).sum())
    oracle = {int(k): int(k) + 5555 for k in keys}
    assert dict(idx.items()) == oracle
    idx.check_invariants()
    assert idx.stats["replayed_ops"] == replayed


def test_whole_domain_crash_abandons_pending_replay():
    """A full powerfail (every shard) is the unsharded contract: the
    in-flight plan is lost, pending per-shard replays are dropped, and
    acked pre-crash state recovers."""
    idx = ShardedIndex(lambda p: PCLHT(p, n_buckets=64), 4)
    keys = list(range(1, 201))
    _load(idx, keys)
    routes = idx.route(np.array(keys, np.int64))
    victim = int(routes[0])
    upd = Plan()
    for k in keys:
        upd.update(k, k + 7777)
    idx.pmems[victim].arm_crash(after_stores=3)
    with pytest.raises(CrashPoint):
        idx.execute(upd, collect_results=False)
    idx.pmem.crash()  # whole-domain powerfail
    idx.recover()
    assert idx.recover_shard(victim) == 0  # nothing pending anymore
    for k in keys:
        got = idx.execute(Plan.from_ops([("lookup", k, 0)])).results[0]
        assert got in (k + 1000, k + 7777)  # prefix-consistent per key


# -- mesh read fan-out ----------------------------------------------------

@pytest.mark.parametrize("name,factory,scheme", [
    ("P-CLHT", lambda p: PCLHT(p, n_buckets=64), "hash"),
    ("P-ART", PART, "prefix"),
])
def test_mesh_read_path_matches_per_shard(name, factory, scheme):
    rng = np.random.default_rng(9)
    idx = ShardedIndex(factory, 4)
    assert idx.scheme == scheme
    keys = np.unique(rng.integers(1, 1 << 60, 400))
    _load(idx, keys)
    probe = np.concatenate([keys[:300],
                            rng.integers(1, 1 << 60, 100)])  # mostly hits
    gets = Plan.from_ops([("lookup", int(k), 0) for k in probe])
    r_ps = idx.execute(gets, mesh=False)
    r_mesh = idx.execute(gets, mesh=True)
    assert r_mesh.mesh and not r_ps.mesh
    assert r_mesh.results == r_ps.results
    assert r_mesh.found == r_ps.found
    assert idx.stats["mesh_plans"] == 1
    # epoch-keyed cache: a write invalidates the stacked runs
    idx.execute(Plan.from_ops([("insert", 123456789, 1)]),
                collect_results=False)
    r2 = idx.execute(Plan.from_ops([("lookup", 123456789, 0)] * 4),
                     mesh=True)
    assert r2.results == [1] * 4


# -- observability: per-shard attribution ---------------------------------

def test_per_shard_span_attribution_sums_to_pmem_counters():
    """The ``shard.plan`` + ``shard.export`` span counter attributes
    must sum EXACTLY to the aggregate ``ShardedPMem`` counter delta —
    on the per-shard path and the mesh path alike."""
    rng = np.random.default_rng(13)
    idx = ShardedIndex(lambda p: PCLHT(p, n_buckets=64), 4)
    keys = np.unique(rng.integers(1, 1 << 60, 400))
    _load(idx, keys)
    gets = Plan.from_ops([("lookup", int(k), 0) for k in keys[:200]])
    obs.reset()
    obs.enable()
    try:
        c0 = idx.pmem.counters.snapshot()
        idx.execute(_random_plan(rng, 300, 1 << 60, scans=False),
                    collect_results=False)          # per-shard path
        idx.execute(gets, mesh=True)                # mesh path (re-export)
        d = idx.pmem.counters.delta(c0)
    finally:
        obs.disable()
    spans = obs.spans("shard.plan") + obs.spans("shard.export")
    assert spans, "sharded execution emitted no per-shard spans"
    for field in ("stores", "loads", "clwb", "fence", "lines_touched"):
        got = sum(sp.attrs.get(field, 0) for sp in spans)
        assert got == getattr(d, field), \
            f"per-shard {field} attribution drifted: {got}"


# -- the public facade ----------------------------------------------------

def test_api_sharded_session_and_streams():
    from repro.api import open_index
    s = open_index("clht", shards=4, n_buckets=64)
    assert s.shards == 4
    assert s.put(5, 7) and s.get(5) == 7
    drv = s.streams(2)
    t = drv.streams[0].submit(Plan.from_ops([("lookup", 5, 0)]))
    drv.run()
    assert t.result == [7]
    s.crash()  # whole-domain powerfail + re-attach: acked data survives
    assert s.get(5) == 7
    with pytest.raises(ValueError):
        open_index("clht", shards=4, pmem=PMem())
    with pytest.raises(AssertionError):
        open_index("clht", shards=3)


def test_api_unsharded_kwargs_pass_through():
    from repro.api import open_index
    s = open_index("clht", n_buckets=32, grow=False)
    assert s.index.grow is False
    assert s.shards == 1


def test_cceh_plan_surface():
    """The CCEH baseline rides the same plan/execute surface as the
    conversions: mixed plans match a dict oracle and batched reads can
    be forced onto the kernel path."""
    from repro.api import open_index
    s = open_index("cceh", depth=2, fixed=True)
    oracle = {}
    with s.pipeline() as p:
        for k in range(1, 120):
            p.put(k, k * 3)
            oracle[k] = k * 3
    assert dict(s.items()) == oracle
    gets = Plan.from_ops([("lookup", k, 0) for k in range(1, 240)])
    res = s.execute(gets, force_kernel=True)
    assert res.results == [oracle.get(k) for k in range(1, 240)]
    assert res.found == len(oracle)
