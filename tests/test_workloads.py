"""Adversarial workload generators (``repro.data.workloads``): the
Zipfian and hot-set samplers must be bit-exact against independent
scalar oracles consuming the same RNG stream, string-key encoding must
round-trip and preserve lexicographic order, schedules must be
deterministic under a fixed seed, and every matrix mix must replay to
the same found/acked/scanned counts on every plan-surface index as the
sequential dict/sorted-dict oracle."""

import bisect

import numpy as np
import pytest

from repro.core import PART, PBwTree, PCLHT, PHOT, PMasstree, PMem
from repro.core.baselines import CCEH, FastFair
from repro.core.ycsb import run_workload
from repro.data.workloads import (MAX_STR_LEN, decode_str, encode_str,
                                  hotset_ranks, matrix_workload, replay,
                                  string_keys, zipf_cdf, zipf_ranks,
                                  zipf_weights)

ORDERED_FACTORIES = [
    ("FAST&FAIR", lambda p: FastFair(p, fixed=True)),
    ("P-BwTree", PBwTree),
    ("P-Masstree", PMasstree),
    ("P-ART", PART),
    ("P-HOT", PHOT),
]
UNORDERED_FACTORIES = [
    ("CCEH", lambda p: CCEH(p, depth=2, fixed=True)),
    ("P-CLHT", lambda p: PCLHT(p, n_buckets=64)),
]
ALL_FACTORIES = ORDERED_FACTORIES + UNORDERED_FACTORIES


# ---------------------------------------------------------------------------
# Zipfian sampler vs an independent scalar oracle
# ---------------------------------------------------------------------------


def _zipf_oracle(n_items, theta, size, seed):
    """Independent scalar re-derivation: per-rank float64 powers, a
    scalar left-to-right partial-sum loop (``np.cumsum`` accumulates
    sequentially, so this reproduces its array bit-exactly), and a
    per-draw bisect over the partial sums."""
    weights = [np.float64(r) ** np.float64(-theta)
               for r in range(1, n_items + 1)]
    cdf = []
    acc = np.float64(0.0)
    for w in weights:
        acc = acc + w
        cdf.append(acc)
    rng = np.random.default_rng(seed)
    u = rng.random(size)  # the same single stream draw the sampler makes
    out = []
    for ui in u:
        x = np.float64(ui) * cdf[-1]
        r = bisect.bisect_right(cdf, x)
        out.append(min(r, n_items - 1))
    return np.asarray(out, np.int64), cdf, u


@pytest.mark.parametrize("theta", [0.0, 0.6, 0.9, 1.2])
def test_zipf_bit_exact_vs_scalar_oracle(theta):
    n_items, size, seed = 257, 4096, 3
    got = zipf_ranks(n_items, theta, size, np.random.default_rng(seed))
    want, cdf, u = _zipf_oracle(n_items, theta, size, seed)
    assert np.array_equal(got, want), \
        f"sampler diverged from scalar oracle at theta={theta}"
    # the vectorized cdf must equal the scalar partial sums bit-for-bit
    assert np.array_equal(zipf_cdf(n_items, theta), np.asarray(cdf))
    # bracket (rejection) check: rank r is legal iff cdf[r-1] <= u*cdf[-1] < cdf[r]
    for ui, r in zip(u[:512], got[:512]):
        x = np.float64(ui) * cdf[-1]
        assert (r == 0 or cdf[r - 1] <= x) and \
            (x < cdf[r] or r == n_items - 1), \
            f"rank {r} outside its CDF bracket for u={ui!r}"


def test_zipf_skew_shape():
    # theta=0 is uniform in law; higher theta concentrates rank 0
    rng = np.random.default_rng(0)
    flat = zipf_ranks(100, 0.0, 20000, rng)
    rng = np.random.default_rng(0)
    skew = zipf_ranks(100, 1.2, 20000, rng)
    assert np.mean(flat == 0) < 0.03 < np.mean(skew == 0)
    w = zipf_weights(5, 1.0)
    assert np.allclose(w, [1, 1 / 2, 1 / 3, 1 / 4, 1 / 5])


# ---------------------------------------------------------------------------
# hot-set sampler vs scalar recombination of the same stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hot_frac,hot_op_frac",
                         [(0.01, 0.9), (0.1, 0.5), (1.0, 0.9)])
def test_hotset_bit_exact_vs_scalar_oracle(hot_frac, hot_op_frac):
    n_items, size, seed = 400, 4096, 5
    got = hotset_ranks(n_items, hot_frac, hot_op_frac, size,
                       np.random.default_rng(seed))
    # oracle: consume the identical three vectorized draws, recombine
    # scalar-wise
    rng = np.random.default_rng(seed)
    n_hot = max(1, int(round(n_items * hot_frac)))
    n_cold = max(n_items - n_hot, 1)
    coin = rng.random(size)
    hot = rng.integers(0, n_hot, size=size)
    cold = rng.integers(0, n_cold, size=size)
    for i in range(size):
        if n_hot >= n_items:
            want = hot[i]
        elif coin[i] < hot_op_frac:
            want = hot[i]
        else:
            want = n_hot + cold[i]
        assert got[i] == want, f"draw {i} diverged"
    if n_hot < n_items:
        hot_share = np.mean(got < n_hot)
        assert abs(hot_share - hot_op_frac) < 0.05


# ---------------------------------------------------------------------------
# string keys
# ---------------------------------------------------------------------------


def test_encode_decode_round_trip():
    rng = np.random.default_rng(9)
    for _ in range(500):
        n = int(rng.integers(1, MAX_STR_LEN + 1))
        b = bytes(rng.integers(1, 256, size=n, dtype=np.uint8))
        k = encode_str(b)
        assert 0 < k < (1 << 59)
        assert decode_str(k) == b
    assert decode_str(encode_str("abc")) == b"abc"


def test_encode_preserves_lexicographic_order():
    rng = np.random.default_rng(10)
    pool = [bytes(rng.integers(1, 256,
                               size=int(rng.integers(1, MAX_STR_LEN + 1)),
                               dtype=np.uint8))
            for _ in range(300)]
    # include adversarial prefix pairs: a proper prefix must sort
    # immediately before its extensions
    pool += [b"a", b"ab", b"abc", b"ab\x01", b"ac", b"b"]
    enc = sorted(set(pool))
    assert enc == sorted(set(pool), key=encode_str)


def test_encode_rejects_bad_keys():
    with pytest.raises(ValueError):
        encode_str("")
    with pytest.raises(ValueError):
        encode_str(b"x" * (MAX_STR_LEN + 1))
    with pytest.raises(ValueError):
        encode_str(b"a\x00b")
    with pytest.raises(ValueError):
        decode_str(1 << 60)  # out of the encoded range
    with pytest.raises(ValueError):
        decode_str(0)


def test_string_keys_clustered_and_unique():
    keys = string_keys(500, n_prefixes=8, prefix_len=3, seed=4)
    assert len(keys) == len(set(keys)) == 500
    decoded = [decode_str(k) for k in keys]
    assert all(len(d) == MAX_STR_LEN for d in decoded)
    prefixes = {d[:3] for d in decoded}
    assert len(prefixes) <= 8  # the shared-prefix pool
    assert string_keys(500, n_prefixes=8, prefix_len=3, seed=4) == keys


# ---------------------------------------------------------------------------
# schedules: determinism + replay equivalence on every index
# ---------------------------------------------------------------------------


def test_matrix_workload_deterministic():
    a = matrix_workload("F", 200, 200, dist="zipfian", theta=0.9, seed=3)
    b = matrix_workload("F", 200, 200, dist="zipfian", theta=0.9, seed=3)
    c = matrix_workload("F", 200, 200, dist="zipfian", theta=0.9, seed=4)
    assert a.load_ops == b.load_ops and a.run_ops == b.run_ops
    assert a.run_ops != c.run_ops
    assert a.meta["theta"] == 0.9 and a.meta["dist"] == "zipfian"


def test_matrix_workload_rejects_unknown_knobs():
    with pytest.raises(ValueError):
        matrix_workload("A", 10, 10, dist="pareto")
    with pytest.raises(ValueError):
        matrix_workload("A", 10, 10, keyspace="tuple")


MIXES = [
    dict(mix="F", dist="zipfian", theta=1.2),
    dict(mix="A", dist="hotset", hot_frac=0.02, hot_op_frac=0.9),
    dict(mix="D", dist="zipfian", theta=0.9),
]
SCAN_MIXES = [
    dict(mix="E", dist="zipfian", theta=0.9),
    dict(mix="E", dist="zipfian", theta=0.9, keyspace="string"),
]


@pytest.mark.parametrize("name,factory", ALL_FACTORIES,
                         ids=[n for n, _ in ALL_FACTORIES])
def test_matrix_mix_replays_exactly(name, factory):
    """Every matrix mix, batched plan path, must produce the replay
    oracle's found/acked/scanned counts on every plan-surface index —
    the ordered indexes additionally on the scan-heavy and string-key
    schedules."""
    mixes = MIXES + [dict(mix="A", dist="zipfian", theta=0.9,
                          keyspace="string")]
    ordered = any(name == n for n, _ in ORDERED_FACTORIES)
    if ordered:
        mixes = mixes + SCAN_MIXES
    for knobs in mixes:
        wl = matrix_workload(n_load=250, n_run=250, seed=13, **knobs)
        idx = factory(PMem())
        run_workload(idx, wl, phase="load", batch_lookups=True)
        done = run_workload(idx, wl, phase="run", batch_lookups=True,
                            max_batch=64)
        want = replay(wl.load_ops, wl.run_ops)
        got = (done["found"], done["acked"], done["scanned"])
        assert got == want.counts(), \
            f"{name} diverged from replay oracle on {knobs}"
        # the surviving key/value state must match the oracle's model
        assert dict(idx.items()) == want.model, \
            f"{name} final state diverged from replay model on {knobs}"


# ---------------------------------------------------------------------------
# deterministic group-commit crash-point sweep (hypothesis-free twin of
# test_properties.py::test_crash_at_every_group_commit_point)
# ---------------------------------------------------------------------------


def _seeded_mixed_ops(seed, n_keys=10):
    """Random mixed insert/update/delete/lookup sequence from a fixed
    seed — same shape as the hypothesis strategy, but runnable where
    hypothesis is not installed."""
    rng = np.random.default_rng(seed)
    keys = [int(k) for k in
            rng.choice(1 << 30, size=n_keys, replace=False) + 1]
    ops = []
    for i, k in enumerate(keys):
        ops.append(("insert", k, (k % 1000003) + 1))
        if rng.random() < 0.5:
            ops.append(("update", k, (k % 999983) + 7))
        if rng.random() < 0.3:
            ops.append(("delete", keys[int(rng.integers(0, i + 1))], 0))
        if rng.random() < 0.3:
            ops.append(("lookup", keys[int(rng.integers(0, i + 1))], 0))
    return ops


@pytest.mark.parametrize("name,factory", ALL_FACTORIES,
                         ids=[n for n, _ in ALL_FACTORIES])
def test_plan_crash_sweep_every_index(name, factory):
    """Crash a batched mixed plan at every sampled outermost
    group-commit boundary: recovery must land every key on a legal
    plan-prefix state, invariants must hold, new writes must succeed,
    and a clean run must reproduce the dict model (all checked inside
    plan_crash_sweep)."""
    from repro.core import plan_crash_sweep
    report = plan_crash_sweep(factory, _seeded_mixed_ops(seed=21),
                              max_points=6)
    assert report.n_crash_states > 0
    assert report.ok, f"{name}: {report.summary()}\n" + "\n".join(
        report.consistency_failures + report.durability_failures
        + report.stall_failures)


def test_replay_oracle_semantics():
    load = [("insert", 5, 50), ("insert", 7, 70)]
    run = [("lookup", 5, 0), ("lookup", 6, 0),     # found: 1
           ("insert", 5, 99),                       # dup -> not acked
           ("insert", 8, 80),                       # acked
           ("update", 9, 90),                       # upsert -> acked
           ("delete", 7, 0), ("delete", 7, 0),      # acked once
           ("scan", 5, 10)]                         # 5, 8, 9 -> 3
    res = replay(load, run)
    assert res.counts() == (1, 3, 3)  # acked: insert 8, update 9, delete 7
    assert res.model == {5: 50, 8: 80, 9: 90}
