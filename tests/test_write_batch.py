"""Sharded batched write path: ``write_batch`` must be positionally
identical to scalar insert/update/delete for every converted index,
recover from crashes landing inside a group-commit epoch, invalidate
only the shards it writes (untouched shards keep serving the existing
snapshot), elide no-op updates, and *amortize* — never hide — the
clwb/fence traffic of the ops it groups."""

import numpy as np
import pytest

from repro.core import (CrashPoint, PMem, PART, PHOT, PBwTree, PCLHT,
                        PMasstree, PMSnapshot)
from repro.core.ycsb import generate, run_workload

RNG = np.random.default_rng(13)

FACTORIES = [
    ("P-CLHT", lambda p: PCLHT(p, n_buckets=64)),
    ("P-ART", PART),
    ("P-HOT", PHOT),
    ("P-Masstree", PMasstree),
    ("P-BwTree", PBwTree),
]


def _mixed_ops(rng, existing, n, clustered=False):
    """insert/update/delete stream; ``clustered`` packs keys into a
    narrow range so tree indexes form multi-op leaf groups."""
    base = int(rng.integers(1, 1 << 59)) if clustered else 0
    ops = []
    for _ in range(n):
        r = rng.random()
        if clustered:
            k = base + int(rng.integers(0, 150))
            if r < 0.5:
                ops.append(("insert", k, (k % 99991) + 1))
            elif r < 0.75:
                ops.append(("update", k, int(rng.integers(1, 1 << 40)) | 1))
            else:
                ops.append(("delete", k, 0))
            continue
        if r < 0.4 or not existing:
            k = int(rng.integers(1, 1 << 60))
            ops.append(("insert", k, (k % 99991) + 1))
            existing.append(k)
        elif r < 0.7:
            k = existing[int(rng.integers(0, len(existing)))]
            ops.append(("update", k, int(rng.integers(1, 1 << 40)) | 1))
        else:
            k = (existing[int(rng.integers(0, len(existing)))]
                 if rng.random() < 0.8 else int(rng.integers(1, 1 << 60)))
            ops.append(("delete", k, 0))
    return ops


def _apply_scalar(idx, ops):
    return [idx._apply_write(kind, int(k), int(v)) for kind, k, v in ops]


@pytest.mark.parametrize("name,factory", FACTORIES)
def test_write_batch_equals_scalar(name, factory):
    """Positional results and final state match scalar op-by-op
    application, for uniform and clustered (leaf-group) key streams."""
    rng = np.random.default_rng(29)
    existing = []
    preload = _mixed_ops(rng, existing, 150)
    ops = _mixed_ops(rng, existing, 300) + _mixed_ops(rng, [], 150,
                                                     clustered=True)
    ia, ib = factory(PMem()), factory(PMem())
    _apply_scalar(ia, preload)
    _apply_scalar(ib, preload)
    scalar = _apply_scalar(ia, ops)
    batched = ib._write_batch(ops)
    assert scalar == batched, [
        (o, s, b) for o, s, b in zip(ops, scalar, batched) if s != b][:5]
    assert sorted(ia.items()) == sorted(ib.items())
    ia.check_invariants()
    ib.check_invariants()
    # group commit closed every epoch: nothing left unpersisted
    ib.pmem.assert_clean()


@pytest.mark.parametrize("name,factory", FACTORIES)
def test_write_batch_same_key_history(name, factory):
    """Ops on one key keep their arrival order (stable partition), so a
    full insert→delete→insert→update→update(no-op) history folds to the
    scalar result even inside one batch."""
    idx = factory(PMem())
    k = 0x1234567
    ops = [("insert", k, 10), ("delete", k, 0), ("insert", k, 20),
           ("update", k, 30), ("update", k, 30)]
    ref = factory(PMem())
    assert idx._write_batch(ops) == _apply_scalar(ref, ops)
    assert idx.lookup(k) == 30


@pytest.mark.parametrize("name,factory", FACTORIES)
def test_mid_group_commit_crash_recovery(name, factory):
    """Crash after each of a sample of atomic stores inside a
    write_batch (the §5 targeted strategy via PMSnapshot restore), then
    powerfail: every pre-batch key must read back, every batch op must
    be atomic (old state or new state, never torn), and new writes must
    succeed on the recovered image."""
    pmem = PMem()
    idx = factory(pmem)
    rng = np.random.default_rng(31)
    pre = {int(k): (int(k) % 99991) + 1
           for k in rng.integers(1, 1 << 60, size=80)}
    for k, v in pre.items():
        idx.insert(k, v)
    victims = list(pre)[:6]
    fresh = [int(k) for k in rng.integers(1 << 60, 1 << 61, size=6)]
    batch = ([("insert", k, k % 1000 + 2) for k in fresh]
             + [("delete", k, 0) for k in victims[:3]]
             + [("update", k, 999999) for k in victims[3:]])
    snap = PMSnapshot(pmem, idx)
    before = pmem.counters.stores
    idx._write_batch(batch)
    n_stores = pmem.counters.stores - before
    snap.restore(pmem)
    assert n_stores > 0
    for k_at in range(0, n_stores, max(1, n_stores // 8)):
        pmem.arm_crash(after_stores=k_at)
        try:
            idx._write_batch(batch)
            pmem.disarm_crash()
        except CrashPoint:
            pass
        pmem.crash(mode="powerfail")
        idx.recover()
        for k, v in pre.items():
            got = idx.lookup(k)
            if k in victims[:3]:
                assert got in (v, None), (k_at, k, got)  # delete: old/absent
            elif k in victims[3:]:
                assert got in (v, 999999), (k_at, k, got)  # update: old/new
            else:
                assert got == v, (k_at, k, got)  # untouched: durable
        for k in fresh:
            assert idx.lookup(k) in (None, k % 1000 + 2), (k_at, k)
        idx.check_invariants()
        # the recovered image accepts and serves new writes
        assert idx.insert(777777777 + k_at, 42)
        assert idx.lookup(777777777 + k_at) == 42
        snap.restore(pmem)


@pytest.mark.parametrize("name,factory", FACTORIES)
def test_untouched_shards_keep_snapshot_epochs(name, factory):
    """write_batch bumps only the shards it wrote; queries routing to
    untouched shards are served from the existing snapshot without a
    re-export (the serving prefix-cache property)."""
    idx = factory(PMem())
    rng = np.random.default_rng(37)
    keys = [int(k) for k in np.unique(rng.integers(1, 1 << 60, size=300))]
    idx._write_batch([("insert", k, (k % 4093) + 1) for k in keys])
    snap_obj = idx.snapshot()
    before = list(idx._effective_shard_epochs())
    # write a batch confined to a few shards
    batch_keys = [int(k) for k in rng.integers(1, 1 << 56, size=12)]
    idx._write_batch([("insert", k, 5) for k in batch_keys])
    after = list(idx._effective_shard_epochs())
    touched = set(int(s) for s in idx.shard_route(
        np.asarray(batch_keys, np.int64)))
    assert touched != set(range(idx.N_WRITE_SHARDS))  # test is meaningful
    for s in range(idx.N_WRITE_SHARDS):
        if s in touched:
            assert after[s] > before[s], s
        else:
            assert after[s] == before[s], s
    # the memoized snapshot object survives a sharded batch…
    assert idx._snapshot is snap_obj
    # …and clean-shard lookups are served from it without re-export
    clean = [k for k, s in zip(
        keys, idx.shard_route(np.asarray(keys, np.int64)).tolist())
        if s not in touched]
    assert len(clean) >= idx._MIN_KERNEL_BATCH
    calls = {"n": 0}
    orig = idx.export_arrays

    def counting_export():
        calls["n"] += 1
        return orig()

    idx.export_arrays = counting_export
    hits_before = idx.shard_stats["refined_queries"]
    got = idx._lookup_batch(clean)
    assert got == [idx.lookup(k) for k in clean]
    assert calls["n"] == 0, "clean-shard batch forced a re-export"
    assert idx.shard_stats["refined_queries"] >= hits_before + len(clean)


@pytest.mark.parametrize("name,factory", FACTORIES)
def test_noop_update_keeps_snapshot_valid(name, factory):
    """Overwriting a key with its current value writes nothing and
    leaves the snapshot fully valid — scalar and batched paths."""
    idx = factory(PMem())
    keys = [int(k) for k in np.unique(
        RNG.integers(1, 1 << 60, size=60))]
    for k in keys:
        idx.insert(k, (k % 4093) + 1)
    s = idx.snapshot()
    k0 = keys[0]
    stores = idx.pmem.counters.stores
    assert idx.update(k0, (k0 % 4093) + 1)  # scalar no-op
    assert idx._write_batch([("update", k, (k % 4093) + 1)
                            for k in keys[:10]]) == [True] * 10
    assert idx.pmem.counters.stores == stores, "no-op updates stored"
    assert idx.snapshot() is s
    # a changed value is a real update and must invalidate its shard
    assert idx.update(k0, 123456789)
    assert idx.lookup(k0) == 123456789
    assert idx.snapshot() is not s


def test_partition_kernel_matches_ref():
    """kernels/partition lane-limb route against the uint64 oracle,
    including keys that stress every 16-bit carry path."""
    from repro.kernels.partition import partition_writes, route_ref, \
        route_shards
    rng = np.random.default_rng(41)
    keys = np.concatenate([
        rng.integers(1, 1 << 62, size=3000),
        rng.integers(1, 1 << 16, size=64),
        [1, 2, (1 << 62) + 5, (1 << 63) - 1],
    ]).astype(np.int64)
    keys[5:20] |= 0x80000000  # low-half sign bit
    keys[25:40] |= (0xFFFF0000FFFF0000 >> 1)  # dense carry chains
    for scheme in ("hash", "prefix"):
        for n in (1, 2, 16, 2048):
            assert (route_ref(keys, n, scheme)
                    == route_shards(keys, n, scheme, use_kernel=True)).all()
    shards, order, offsets = partition_writes(keys, 16, "prefix")
    assert offsets[-1] == len(keys)
    assert (np.diff(shards[order]) >= 0).all()  # sorted by shard
    for s in range(16):  # stable within each shard
        run = order[offsets[s]:offsets[s + 1]]
        assert (np.diff(run) > 0).all()


@pytest.mark.parametrize("name,factory", [
    ("P-CLHT", lambda p: PCLHT(p, n_buckets=512)),
    ("P-Masstree", PMasstree),
    ("P-BwTree", PBwTree),
])
def test_group_commit_amortizes_persist_traffic(name, factory):
    """Per-insert clwb/fence through write_batch must not exceed the
    scalar path (group commit amortizes; the close still flushes every
    dirtied line once and fences once per shard run)."""
    rng = np.random.default_rng(43)
    keys = [int(k) for k in np.unique(rng.integers(1, 1 << 60, size=600))]
    load, fresh = keys[:400], keys[400:]
    scalar_pm = PMem()
    ia = factory(scalar_pm)
    for k in load:
        ia.insert(k, k % 97 + 1)
    c0 = scalar_pm.counters.snapshot()
    for k in fresh:
        ia.insert(k, 7)
    cs = scalar_pm.counters.delta(c0)
    batch_pm = PMem()
    ib = factory(batch_pm)
    for k in load:
        ib.insert(k, k % 97 + 1)
    c0 = batch_pm.counters.snapshot()
    ib._write_batch([("insert", k, 7) for k in fresh])
    cb = batch_pm.counters.delta(c0)
    n = len(fresh)
    assert cb.clwb / n <= cs.clwb / n + 1e-9, (cb.clwb, cs.clwb)
    assert cb.fence / n <= cs.fence / n + 1e-9, (cb.fence, cs.fence)
    assert sorted(ia.items()) == sorted(ib.items())
    batch_pm.assert_clean()


def test_group_commit_defers_and_closes():
    """Unit semantics of PMem.group_commit: clwb/fence defer inside the
    epoch (counted once per line + one fence at close), and a crash
    mid-epoch abandons the un-acked group entirely."""
    pmem = PMem()
    r = pmem.alloc("gc", 64)
    pmem.persist_region(r)
    c0 = pmem.counters.snapshot()
    with pmem.group_commit():
        for i in range(8):  # one cache line, stored 8 times
            pmem.store(r, i, i + 1)
            pmem.clwb(r, i)
            pmem.fence()
        d = pmem.counters.delta(c0)
        assert d.clwb == 0 and d.fence == 0  # all deferred
    d = pmem.counters.delta(c0)
    assert d.clwb == 1 and d.fence == 1  # once per line + commit fence
    pmem.assert_clean()
    assert [int(r.pm[i]) for i in range(8)] == list(range(1, 9))
    # crash inside the epoch: nothing of the group becomes durable
    c0 = pmem.counters.snapshot()
    pmem.arm_crash(after_stores=4)
    with pytest.raises(CrashPoint):
        with pmem.group_commit():
            for i in range(8):
                pmem.store(r, i, 100 + i)
                pmem.clwb(r, i)
                pmem.fence()
    pmem.crash(mode="powerfail")
    assert [int(r.pm[i]) for i in range(8)] == list(range(1, 9))
    assert pmem.counters.delta(c0).fence == 0  # the epoch never closed


@pytest.mark.parametrize("wl_name", ["A", "D", "F"])
def test_executor_write_coalescing_counts(wl_name):
    """PhaseExecutor's write buffering preserves every observable op
    result on the write-heavy YCSB mixes (conflicting reads flush the
    write buffer, so reordering is only ever between commuting ops)."""
    for factory in (lambda p: PCLHT(p, n_buckets=256), PMasstree):
        wl = generate(wl_name, 500, 400, seed=17)
        ia, ib = factory(PMem()), factory(PMem())
        run_workload(ia, wl, phase="load")
        run_workload(ib, wl, phase="load")
        scalar = run_workload(ia, wl, phase="run")
        batched = run_workload(ib, wl, phase="run", batch_lookups=True,
                               max_batch=64)
        for key in ("insert", "update", "delete", "lookup", "found",
                    "acked"):
            assert scalar[key] == batched[key], (wl_name, key)
        assert batched["write_batches"] > 0
        assert sorted(ia.items()) == sorted(ib.items())


def test_serving_ingest_keeps_warm_shards():
    """Prefix-cache ingest through write_batch leaves warm shards'
    snapshot epochs intact: a later admission's prefix probe serves
    them from the existing export (no re-export, counted in
    shard_stats) while still returning exact results."""
    from repro.serving.engine import PagedKVManager
    pmem = PMem()
    kv = PagedKVManager(pmem, n_pages=512, page_size=4)
    rng = np.random.default_rng(3)
    for _ in range(20):
        toks = [int(t) for t in rng.integers(1, 1000, size=16)]
        kv.prefix_insert(toks, [kv.alloc_page() for _ in range(4)])
    warm = [int(t) for t in rng.integers(1, 1000, size=128)]  # 32 blocks
    kv.prefix_insert(warm, [kv.alloc_page() for _ in range(32)])
    covered, _ = kv.prefix_lookup(warm)
    assert covered == len(warm)
    # steady serving keeps a warm export (decode/warmup probes force it)
    kv.prefix._lookup_batch(kv._block_hashes(warm), force_kernel=True)
    before = kv.prefix.shard_stats["refined_queries"]
    toks2 = [int(t) for t in rng.integers(1001, 2000, size=16)]
    kv.prefix_insert(toks2, [kv.alloc_page() for _ in range(4)])
    covered2, _ = kv.prefix_lookup(warm)
    assert covered2 == len(warm)
    assert kv.prefix.shard_stats["refined_queries"] > before
