#!/usr/bin/env python3
"""Docs link-checker — the CI docs job.

Fails (exit 1) when:

* a relative markdown link in README.md, docs/, EXPERIMENTS.md, or a
  kernel package README resolves to a missing file;
* a ``kernels/<name>`` reference in the checked documents names a
  kernel package that does not exist under src/repro/kernels/
  (dangling kernel-package references);
* one of the index/plan kernel packages (probe, clht_probe,
  art_probe, scan, partition, conflict) is missing its README.md;
* the top-level README.md, docs/ARCHITECTURE.md, docs/PMEM_MODEL.md,
  or docs/API.md is missing;
* docs/API.md stops documenting the public plan surface (the
  ``execute``/``Plan``/``Session``/``pipeline`` anchor terms) or
  loses the migration table from the pre-plan ``*_batch`` calls;
* docs/WORKLOADS.md stops documenting the adversarial-matrix surface
  (samplers, string-key encoding, deferral metric, crash sweep);
* docs/PMEM_MODEL.md stops documenting the fingerprint-lane /
  optimistic-read surface (fp64, pm_load_words, validation_points) or
  docs/ARCHITECTURE.md drops the kernel-table fp rows;
* docs/RECOVERY.md stops documenting the instant-recovery SLO surface
  (the chaos-harness metrics, the DRAM-rebuild baseline) or
  docs/ARCHITECTURE.md drops the pipelined-tick section.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
KERNELS = ROOT / "src" / "repro" / "kernels"
README_REQUIRED = ("probe", "clht_probe", "art_probe", "scan", "partition",
                   "conflict")
TOP_DOCS_REQUIRED = ("README.md", "docs/ARCHITECTURE.md",
                     "docs/PMEM_MODEL.md", "docs/API.md",
                     "docs/OBSERVABILITY.md", "docs/SHARDING.md",
                     "docs/WORKLOADS.md", "docs/RECOVERY.md")
# the public-surface anchors docs/API.md must keep documenting
API_DOC_ANCHORS = ("execute", "Plan", "Session", "pipeline",
                   "open_index", "lookup_batch", "scan_batch",
                   "write_batch")
# the telemetry surface docs/OBSERVABILITY.md must keep documenting
OBS_DOC_ANCHORS = ("obs.span", "plan.wave", "pmem.group_commit",
                   "recovery.time_to_first_served", "MetricsRegistry",
                   "Histogram", "--trace", "pipeline_depth",
                   "admit_queue_depth", "async_export_backlog",
                   "pipeline.coalesce")
# the recovery-SLO surface docs/RECOVERY.md must keep documenting
RECOVERY_DOC_ANCHORS = ("time_to_first_served_us", "warm_prefix_hit_rate",
                        "requests_lost", "requests_replayed",
                        "dram_rebuild_us", "instant_recovery_speedup",
                        "group_commit_boundaries", "AsyncExporter",
                        "crash_and_recover", "--smoke")
# the scale-out surface docs/SHARDING.md must keep documenting
SHARDING_DOC_ANCHORS = ("ShardedIndex", "split_by_shard", "StreamDriver",
                        "crash_shard", "recover_shard", "mesh_lookup",
                        "shard.plan", "Reporting model", "critical_ns",
                        "--shards")
# the adversarial-matrix surface docs/WORKLOADS.md must keep documenting
WORKLOADS_DOC_ANCHORS = ("zipf_ranks", "hotset_ranks", "encode_str",
                         "string_keys", "matrix_workload", "replay",
                         "deferred_plans", "prefix@55", "clwb_per_op",
                         "plan_crash_sweep", "--smoke")
# the probe/persistence surface docs/PMEM_MODEL.md must keep documenting
PMEM_DOC_ANCHORS = ("fp64", "fp_partial", "FP_EMPTY", "pm_load_words",
                    "fp_false_positives", "optimistic_retries",
                    "write_version_", "validation_points",
                    "group_commit", "arm_crash")
# the kernel map docs/ARCHITECTURE.md must keep documenting
ARCH_DOC_ANCHORS = ("fingerprint lane", "probe64_fp", "leaf_fp",
                    "_optimistic_lookup", "_write_batch",
                    "_shard_refine", "PlanPipeline", "AsyncExporter",
                    "submit_if_stale", "pipelined=True")

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
KERNEL_REF_RE = re.compile(r"\bkernels/([A-Za-z0-9_]+)")


def doc_files():
    docs = [ROOT / "README.md"]
    docs += sorted((ROOT / "docs").glob("**/*.md"))
    docs += [ROOT / "EXPERIMENTS.md"]
    docs += sorted(KERNELS.glob("*/README.md"))
    return [p for p in docs if p.exists()]


def check_file(path: pathlib.Path, kernel_pkgs: set) -> list:
    errors = []
    text = path.read_text()
    rel = path.relative_to(ROOT)
    for m in LINK_RE.finditer(text):
        target = m.group(1).strip()
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#")[0]
        if not target:
            continue
        if not (path.parent / target).resolve().exists():
            errors.append(f"{rel}: dangling link -> {m.group(1)}")
    for m in KERNEL_REF_RE.finditer(text):
        if m.group(1) not in kernel_pkgs:
            errors.append(f"{rel}: dangling kernel-package reference -> "
                          f"kernels/{m.group(1)}")
    return errors


def main() -> int:
    kernel_pkgs = {p.name for p in KERNELS.iterdir() if p.is_dir()}
    errors = []
    files = doc_files()
    for rel in TOP_DOCS_REQUIRED:
        if not (ROOT / rel).exists():
            errors.append(f"{rel} is missing")
    for name in README_REQUIRED:
        if not (KERNELS / name / "README.md").exists():
            errors.append(f"src/repro/kernels/{name}/README.md is missing")
    api_doc = ROOT / "docs" / "API.md"
    if api_doc.exists():
        api_text = api_doc.read_text()
        for anchor in API_DOC_ANCHORS:
            if anchor not in api_text:
                errors.append(f"docs/API.md no longer documents "
                              f"{anchor!r} (public-surface drift)")
    obs_doc = ROOT / "docs" / "OBSERVABILITY.md"
    if obs_doc.exists():
        obs_text = obs_doc.read_text()
        for anchor in OBS_DOC_ANCHORS:
            if anchor not in obs_text:
                errors.append(f"docs/OBSERVABILITY.md no longer documents "
                              f"{anchor!r} (telemetry-surface drift)")
    shard_doc = ROOT / "docs" / "SHARDING.md"
    if shard_doc.exists():
        shard_text = shard_doc.read_text()
        for anchor in SHARDING_DOC_ANCHORS:
            if anchor not in shard_text:
                errors.append(f"docs/SHARDING.md no longer documents "
                              f"{anchor!r} (scale-out-surface drift)")
    wl_doc = ROOT / "docs" / "WORKLOADS.md"
    if wl_doc.exists():
        wl_text = wl_doc.read_text()
        for anchor in WORKLOADS_DOC_ANCHORS:
            if anchor not in wl_text:
                errors.append(f"docs/WORKLOADS.md no longer documents "
                              f"{anchor!r} (matrix-surface drift)")
    rec_doc = ROOT / "docs" / "RECOVERY.md"
    if rec_doc.exists():
        rec_text = rec_doc.read_text()
        for anchor in RECOVERY_DOC_ANCHORS:
            if anchor not in rec_text:
                errors.append(f"docs/RECOVERY.md no longer documents "
                              f"{anchor!r} (recovery-SLO drift)")
    pmem_doc = ROOT / "docs" / "PMEM_MODEL.md"
    if pmem_doc.exists():
        pmem_text = pmem_doc.read_text()
        for anchor in PMEM_DOC_ANCHORS:
            if anchor not in pmem_text:
                errors.append(f"docs/PMEM_MODEL.md no longer documents "
                              f"{anchor!r} (probe-surface drift)")
    arch_doc = ROOT / "docs" / "ARCHITECTURE.md"
    if arch_doc.exists():
        arch_text = arch_doc.read_text()
        for anchor in ARCH_DOC_ANCHORS:
            if anchor not in arch_text:
                errors.append(f"docs/ARCHITECTURE.md no longer documents "
                              f"{anchor!r} (kernel-map drift)")
    for path in files:
        errors.extend(check_file(path, kernel_pkgs))
    for e in errors:
        print(f"FAIL {e}")
    print(f"checked {len(files)} docs, {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
